"""Unit tests for DVR bookkeeping, the multi-window speculation pipeline,
and the sampler (host-side logic)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dvr, pipeline
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import sample_batch, sample_token, sample_window


def _req(committed, candidates, max_new=100, det=True):
    r = Request(rid=0, prompt=[1, 2, 3],
                sampling=SamplingParams(max_new_tokens=max_new,
                                        is_deterministic=det))
    r.committed = list(committed)
    r.candidates = list(candidates)
    return r


class TestDVRBookkeeping:
    def test_full_match_commits_all_plus_one(self):
        r = _req([10], [20, 30, 40])
        dvr.apply_verify_result(r, n_match=3, commit_tok=50)
        assert r.committed == [10, 20, 30, 40, 50]
        assert r.candidates == []
        assert r.num_rollbacks == 0

    def test_mismatch_commits_prefix_plus_verifier_token(self):
        r = _req([10], [20, 30, 40])
        dvr.apply_verify_result(r, n_match=1, commit_tok=99)
        assert r.committed == [10, 20, 99]
        assert r.num_rollbacks == 1
        assert r.num_recomputed_tokens == 2  # 30, 40 discarded

    def test_immediate_mismatch_still_progresses(self):
        r = _req([10], [20, 30])
        dvr.apply_verify_result(r, n_match=0, commit_tok=77)
        assert r.committed == [10, 77]  # >= 1 new token: forward progress
        assert r.num_recomputed_tokens == 2

    def test_budget_clamp(self):
        r = _req([10, 11, 12], [20], max_new=4)
        dvr.apply_verify_result(r, n_match=1, commit_tok=50)
        assert len(r.committed) == 4

    @settings(max_examples=30, deadline=None)
    @given(n_cand=st.integers(0, 7), n_match=st.integers(0, 7))
    def test_progress_invariant(self, n_cand, n_match):
        r = _req([1], list(range(100, 100 + n_cand)))
        before = len(r.committed)
        dvr.apply_verify_result(r, n_match=n_match, commit_tok=5)
        assert len(r.committed) >= before + 1  # ALWAYS >= 1 new token
        assert len(r.committed) <= before + n_cand + 1

    def test_build_verify_row_shapes(self):
        r = _req([10, 11], [20, 30])
        inputs, cand, cl, sp, ob = dvr.build_verify_row(r, window=5)
        assert inputs == [11, 20, 30, 0, 0]  # last committed + cands + pad
        assert cand == [20, 30, -1, -1]
        assert cl == 2
        assert sp == 3 + 2 - 1  # prompt_len + committed - 1
        assert ob == 2

    def test_ready_for_verify(self):
        r = _req([10], [20, 30, 40, 50], det=True)
        assert dvr.ready_for_verify(r, window=5)  # 4 == W-1 candidates
        r2 = _req([10], [20], det=True, max_new=100)
        assert not dvr.ready_for_verify(r2, window=5)
        r3 = _req([10], [20], det=True, max_new=2)  # done decoding
        assert dvr.ready_for_verify(r3, window=5)
        r4 = _req([10], [20, 30, 40, 50], det=False)
        assert not dvr.ready_for_verify(r4, window=5)

    def test_ready_for_verify_depth_gates_the_pipeline(self):
        """depth bounds windows in flight per request: at the bound the
        request waits for a verdict; deeper bounds re-open submission."""
        r = _req([10], [20, 30, 40, 50], det=True)
        pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        r.candidates = [60, 70, 80, 90]  # next window full
        assert not dvr.ready_for_verify(r, window=5)  # default depth 1
        assert dvr.ready_for_verify(r, window=5, depth=2)
        pipeline.submit_window(r, window=5, submitted_at=2, ready_at=3)
        r.candidates = [61, 71, 81, 91]
        assert not dvr.ready_for_verify(r, window=5, depth=2)
        assert dvr.ready_for_verify(r, window=5, depth=3)

    def test_ready_for_verify_eager_partial_window(self):
        """min_candidates lowers the readiness bar (AdaptivePolicy's eager
        verification for demoted requests) but never below one candidate
        and never above the full window."""
        r = _req([10], [20], det=True, max_new=100)
        assert dvr.ready_for_verify(r, window=5, min_candidates=1)
        assert not dvr.ready_for_verify(r, window=5, min_candidates=2)
        assert dvr.ready_for_verify(r, window=5, min_candidates=0)  # floor 1
        full = _req([10], [20, 30, 40, 50], det=True)
        # min_candidates above W-1 clamps to the window
        assert dvr.ready_for_verify(full, window=5, min_candidates=99)
        empty = _req([10], [], det=True)
        assert not dvr.ready_for_verify(empty, window=5, min_candidates=1)


class TestAcceptanceTelemetry:
    """accept_ema: the per-request acceptance EMA AdaptivePolicy reads."""

    def test_sync_verdict_updates_ema(self):
        r = _req([10], [20, 30, 40, 50])
        assert r.accept_ema == 1.0  # optimistic start
        dvr.apply_verify_result(r, n_match=0, commit_tok=99)
        assert r.accept_ema == pytest.approx(0.5)  # alpha=0.5, sample 0.0

    def test_inflight_verdict_updates_ema(self):
        r = _req([10], [20, 30, 40, 50])
        fl = pipeline.submit_window(r, window=5, submitted_at=1.0,
                                    ready_at=2.0)
        fl.n_match, fl.commit_tok = 2, 77
        pipeline.splice_front(r, window=5)
        assert r.accept_ema == pytest.approx(0.75)  # sample 2/4

    def test_normalized_window_ema_counts_the_popped_head(self):
        """Front normalization pops a chained window's first candidate
        (it was ACCEPTED — committed as the predecessor's commit token);
        the EMA sample must still count it on both sides, else a 1-of-4
        verdict reads as 0-of-3 and drags the EMA toward demotion."""
        r = _req([10], [20, 30, 40, 50, 60, 70, 80, 90])
        a = pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        b = pipeline.submit_window(r, window=5, submitted_at=2, ready_at=3)
        a.n_match, a.commit_tok = 4, 60  # full match, agrees with b.cands[0]
        b.n_match, b.commit_tok = 1, 99  # device verdict: 1 of 4 accepted
        pipeline.splice_front(r, window=5)  # normalizes b: 0 of 3 + shifted
        assert (b.n_match, b.shifted, len(b.cands)) == (0, 1, 3)
        pipeline.splice_front(r, window=5)
        # samples: 4/4 (ema stays 1.0), then 1/4 -> ema 1 + 0.5*(0.25 - 1)
        assert r.accept_ema == pytest.approx(0.625)

    def test_cascaded_windows_do_not_update_ema(self):
        """Cascade-discarded windows never spliced: their tokens fell to an
        EARLIER window's rollback, so only the spliced verdict's sample
        enters the EMA (double-punishing the flip would crater it)."""
        r = _req([10], [20, 30, 40, 50, 60, 61, 62, 63])
        a = pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        b = pipeline.submit_window(r, window=5, submitted_at=2, ready_at=3)
        a.n_match, a.commit_tok = 0, 99  # rollback; b cascades away
        b.n_match, b.commit_tok = 4, 77
        pipeline.splice_front(r, window=5)
        assert r.pipeline == []
        assert r.accept_ema == pytest.approx(0.5)  # one sample of 0/4

    def test_partial_window_counts_submitted_fraction(self):
        """An eager 1-candidate verdict weighs the same as a full window:
        the sample is n_match / submitted, so the EMA tracks flip
        probability, not window pacing."""
        r = _req([10], [20])
        dvr.apply_verify_result(r, n_match=1, commit_tok=30)
        assert r.accept_ema == 1.0  # 1/1 accepted: no decay
        r2 = _req([10], [20])
        dvr.apply_verify_result(r2, n_match=0, commit_tok=99)
        assert r2.accept_ema == pytest.approx(0.5)

    def test_ema_converges_under_constant_rollback(self):
        r = _req([10], [])
        for _ in range(6):
            r.candidates = [20, 30, 40, 50]
            dvr.apply_verify_result(r, n_match=0, commit_tok=99)
        assert r.accept_ema < 0.02  # demoted long before this

    def test_recovery_promotes(self):
        r = _req([10], [])
        r.accept_ema = 0.1
        for _ in range(3):
            r.candidates = [20, 30]
            dvr.apply_verify_result(r, n_match=2, commit_tok=40)
        assert r.accept_ema > 0.8  # above the promote threshold


class TestInflightVerify:
    """Single-window in-flight bookkeeping (the depth-1 protocol, now the
    FIFO's base case)."""

    def _submit(self, committed, window_cands, past, window=5):
        r = _req(committed, list(window_cands) + list(past))
        fl = pipeline.submit_window(r, window=window, submitted_at=1,
                                    ready_at=1)
        assert fl.cands == list(window_cands)
        assert r.candidates == list(past)
        return r

    def test_submit_moves_window_out(self):
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        # window is out for verification; speculation continues behind it
        assert r.pipeline[0].cands == [20, 30, 40, 50]
        assert r.pipeline[0].cond_tok == 10  # anchored on committed[-1]
        assert r.total_generated == 1 + 4 + 2
        assert r.window_seq == 1
        assert not dvr.ready_for_verify(r, window=5)  # depth-1 FIFO full

    def test_full_match_agreeing_tail_survives(self):
        """Full match + commit token == first speculated-past token: the
        continuation was conditioned on exactly what got committed, so the
        remaining speculation stays valid."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        r.pipeline[0].n_match, r.pipeline[0].commit_tok = 4, 60
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 40, 50, 60]
        assert r.candidates == [61]  # 60 was subsumed by the commit
        assert r.pipeline == []
        assert r.num_rollbacks == 0
        assert not out.rolled_back
        assert not out.restore_state  # surviving speculation: live state OK
        # …but the FIFO drained: the next window launches anchored, so the
        # replay anchor must advance to this window's checkpoint
        assert out.reanchor

    def test_full_match_disagreeing_tail_invalidated(self):
        """Full match but the verifier's next token differs from the first
        speculated-past token: everything decoded past the window descends
        from a rolled-back token and must be recomputed."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61, 62])
        r.pipeline[0].n_match, r.pipeline[0].commit_tok = 4, 99
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 40, 50, 99]
        assert r.candidates == []
        assert r.num_rollbacks == 1
        assert r.num_recomputed_tokens == 3  # 60, 61, 62
        assert out.rolled_back and out.restore_state

    def test_window_mismatch_invalidates_past_speculation(self):
        """Rollback inside the window reaches THROUGH to the speculated-past
        tokens: they extend a rejected candidate."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        r.pipeline[0].n_match, r.pipeline[0].commit_tok = 1, 77
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 77]
        assert r.candidates == []
        assert r.num_rollbacks == 1
        # 30, 40, 50 rejected in-window + 60, 61 speculated past it
        assert r.num_recomputed_tokens == 5
        assert out.rolled_back and out.restore_state

    def test_no_tail_full_match(self):
        r = self._submit([10], [20, 30], [])
        r.pipeline[0].n_match, r.pipeline[0].commit_tok = 2, 44
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 44]
        assert r.num_rollbacks == 0
        # clean splice, but nothing survives it: the live recurrent state
        # lags committed by one consumed token — restore closes the gap
        assert not out.rolled_back and out.restore_state

    def test_budget_clamp_drops_tail(self):
        r = self._submit([10], [20, 30, 40, 50], [60, 61], window=5)
        r.sampling.max_new_tokens = 6
        r.pipeline[0].n_match, r.pipeline[0].commit_tok = 4, 60
        pipeline.splice_front(r)
        assert len(r.committed) == 6
        assert r.candidates == []  # budget reached: speculation moot

    def test_progress_invariant_inflight(self):
        for n_match in range(5):
            for past in ([], [60], [60, 61]):
                r = self._submit([1], [20, 30, 40, 50], past)
                r.pipeline[0].n_match = n_match
                r.pipeline[0].commit_tok = 5
                before = len(r.committed)
                pipeline.splice_front(r)
                assert len(r.committed) >= before + 1
                assert r.pipeline == []


class TestMultiWindowPipeline:
    """Depth > 1: chained submission, in-order splicing, front
    normalization, and cascading invalidation (tentpole protocol)."""

    def _deep_req(self, windows, past=(), committed=(10,), window=5,
                  max_new=100):
        """Submit len(windows) windows back to back; ``windows`` is a list
        of candidate lists (each <= W-1 long, taken contiguously)."""
        toks = [t for w in windows for t in w] + list(past)
        r = _req(list(committed), toks, max_new=max_new)
        for i, w in enumerate(windows):
            fl = pipeline.submit_window(
                r, window=len(w) + 1 if len(w) < window - 1 else window,
                submitted_at=i, ready_at=i + 1, ring_idx=i,
            )
            assert fl.cands == list(w)
        assert r.candidates == list(past)
        return r

    def test_chained_submission_conditions_on_predecessor(self):
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]])
        assert r.pipeline[0].cond_tok == 10  # anchored
        assert r.pipeline[1].cond_tok == 50  # chained on window 1's tail
        assert r.window_seq == 2
        assert pipeline.spec_len(r) == 8
        assert pipeline.conditioning_token(r) == 90

    def test_full_chain_splices_with_shift(self):
        """Window 2's first candidate occupies window 1's commit-token
        position; on an agreeing full match it is popped (already
        committed) and window 2 splices shifted by one."""
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]], past=[95])
        a, b = r.pipeline
        a.n_match, a.commit_tok = 4, 60  # full match, agrees with b.cands[0]
        b.n_match, b.commit_tok = 4, 95  # full match, agrees with past head
        out1 = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 40, 50, 60]
        assert r.pipeline == [b]
        assert b.cands == [70, 80, 90] and b.n_match == 3  # normalized
        assert not out1.rolled_back and not out1.restore_state
        assert not out1.reanchor  # window 2 still in flight: chained anchor
        out2 = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 40, 50, 60, 70, 80, 90, 95]
        assert r.candidates == []  # 95 subsumed by window 2's commit token
        assert r.num_rollbacks == 0
        assert not out2.rolled_back
        assert out2.restore_state  # nothing survives: anchor the state

    def test_rollback_cascades_through_later_windows(self):
        """A rollback in window k discards windows k+1..n AND the fresh
        tail — they all descend from a rejected token."""
        r = self._deep_req(
            [[20, 30, 40, 50], [60, 70, 80, 90]], past=[95, 96]
        )
        a, b = r.pipeline
        a.n_match, a.commit_tok = 2, 77  # rollback inside window 1
        b.n_match, b.commit_tok = 4, 95
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 77]
        assert r.pipeline == [] and r.candidates == []
        assert out.rolled_back and out.restore_state
        assert out.cascaded == [b]
        assert r.num_cascaded_windows == 1
        assert r.num_rollbacks == 1
        # 40, 50 in-window + 60..90 cascaded + 95, 96 fresh = 8
        assert r.num_recomputed_tokens == 8

    def test_full_match_disagreeing_successor_cascades(self):
        """Full match whose commit token differs from the next window's
        first candidate: the successor extends a token the verifier never
        committed — cascade, exactly like an in-window rollback."""
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]])
        a, b = r.pipeline
        a.n_match, a.commit_tok = 4, 61  # full match, but 61 != 60
        b.n_match, b.commit_tok = 4, 95
        out = pipeline.splice_front(r)
        assert r.committed == [10, 20, 30, 40, 50, 61]
        assert r.pipeline == []
        assert out.rolled_back and out.cascaded == [b]
        assert r.num_recomputed_tokens == 4  # window 2's candidates

    def test_in_order_splicing_gates_early_verdicts(self):
        """A ready verdict behind an unready front must wait: only the
        front may splice, however early later landings arrived."""
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]])
        a, b = r.pipeline
        a.n_match, a.commit_tok = 4, 60
        a.ready_at = 10.0  # front lands LATE
        b.n_match, b.commit_tok = 4, 91
        b.ready_at = 2.0  # second lands EARLY
        assert pipeline.apply_ready(r, window=5, now=5.0) == []
        assert r.committed == [10]  # nothing moved
        outs = pipeline.apply_ready(r, window=5, now=10.0)
        assert [o.record for o in outs] == [a, b]  # both land, in order
        assert r.committed == [10, 20, 30, 40, 50, 60, 70, 80, 90, 91]

    def test_pending_front_blocks_ready_successor(self):
        """A front whose device result is still pending (n_match < 0)
        blocks the FIFO even past both deadlines."""
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]])
        b = r.pipeline[1]
        b.n_match, b.commit_tok = 4, 91
        assert pipeline.apply_ready(r, window=5, now=100.0) == []

    def test_budget_clamp_flushes_inflight_windows(self):
        """Committed reaching the budget moots windows still in flight."""
        r = self._deep_req([[20, 30, 40, 50], [60, 70, 80, 90]], max_new=6)
        a, b = r.pipeline
        a.n_match, a.commit_tok = 4, 60
        b.n_match, b.commit_tok = 4, 95
        out = pipeline.splice_front(r)
        assert len(r.committed) == 6  # budget
        assert r.pipeline == [] and r.candidates == []
        assert r.finished()
        # the mooted window counts as discarded (depth accounting and the
        # cascade telemetry must see it) without rollback semantics
        assert b in out.cascaded
        assert r.num_cascaded_windows == 1
        assert r.num_rollbacks == 0

    def test_three_window_chain_then_tail_rollback(self):
        """Chains survive window by window until the LAST window's commit
        token disagrees with the fresh tail."""
        r = self._deep_req(
            [[20, 30, 40, 50], [60, 70, 80, 90], [95, 96, 97, 98]],
            past=[99],
        )
        a, b, c = r.pipeline
        a.n_match, a.commit_tok = 4, 60
        b.n_match, b.commit_tok = 4, 95
        c.n_match, c.commit_tok = 4, 55  # full match but 55 != 99
        for _ in range(3):
            out = pipeline.splice_front(r)
        assert r.committed == [
            10, 20, 30, 40, 50, 60, 70, 80, 90, 95, 96, 97, 98, 55
        ]
        assert out.rolled_back  # the fresh tail [99] was invalidated
        assert r.num_recomputed_tokens == 1
        assert r.num_cascaded_windows == 0


class TestStateMachine:
    """AWAITING_VERIFY wiring: the state is truthful, not decorative.

    A det request is AWAITING_VERIFY exactly while it cannot take a
    fast-path token because it is gated on verification — window full, or
    budget covered by outstanding speculation.  Every verdict (sync or
    in-flight) returns it to RUNNING."""

    def test_window_full_awaits_verify(self):
        r = _req([10], [20, 30, 40])
        r.state = State.RUNNING
        r.candidates.append(50)  # 4 == W-1 for window 5
        dvr.mark_window_state(r, window=5)
        assert r.state is State.AWAITING_VERIFY

    def test_partial_window_keeps_running(self):
        r = _req([10], [20])
        r.state = State.RUNNING
        dvr.mark_window_state(r, window=5)
        assert r.state is State.RUNNING

    def test_budget_covered_by_speculation_awaits(self):
        r = _req([10], [20, 30], max_new=3)  # total_generated == budget
        r.state = State.RUNNING
        dvr.mark_window_state(r, window=5)
        assert r.state is State.AWAITING_VERIFY

    def test_sync_verdict_returns_to_running(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        dvr.apply_verify_result(r, n_match=2, commit_tok=99)
        assert r.state is State.RUNNING

    def test_submit_window_resumes_speculation(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        assert r.state is State.RUNNING  # window out: decoding resumes

    def test_submit_window_with_exhausted_budget_stays_awaiting(self):
        r = _req([10], [20, 30, 40, 50], max_new=5)
        r.state = State.AWAITING_VERIFY
        pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        assert r.state is State.AWAITING_VERIFY

    def test_inflight_verdict_returns_to_running(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        fl = pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        fl.n_match, fl.commit_tok = 4, 60
        pipeline.splice_front(r, window=5)
        assert r.state is State.RUNNING

    def test_inflight_verdict_stays_awaiting_when_leftovers_cover_budget(self):
        """Truthfulness after an in-flight verdict: if surviving
        speculated-past candidates already cover the output budget, the
        request still cannot take a fast-path token — it awaits the next
        verify launch, not decoding."""
        r = _req([10], [20, 30, 40, 50, 60, 61], max_new=7)
        fl = pipeline.submit_window(r, window=5, submitted_at=1, ready_at=2)
        fl.n_match, fl.commit_tok = 4, 60  # full match, tail survives
        pipeline.splice_front(r, window=5)
        assert r.committed == [10, 20, 30, 40, 50, 60]
        assert r.candidates == [61]  # 6 committed + 1 candidate == budget 7
        assert r.done_decoding()
        assert r.state is State.AWAITING_VERIFY

    def test_finished_is_never_clobbered(self):
        r = _req([10], [20])
        r.state = State.FINISHED
        dvr.apply_verify_result(r, n_match=1, commit_tok=30)
        assert r.state is State.FINISHED


class TestSampler:
    def test_greedy_first_max_tiebreak(self):
        logits = jnp.array([0.0, 5.0, 5.0, 1.0])
        tok = sample_token(logits, jnp.int32(0), jnp.int32(0), jnp.float32(0.0))
        assert int(tok) == 1

    def test_stochastic_is_positionally_keyed(self):
        logits = jax.random.normal(jax.random.key(0), (64,))
        t = jnp.float32(0.9)
        a = sample_token(logits, jnp.int32(7), jnp.int32(3), t)
        b = sample_token(logits, jnp.int32(7), jnp.int32(3), t)
        c = sample_token(logits, jnp.int32(7), jnp.int32(4), t)
        d = sample_token(logits, jnp.int32(8), jnp.int32(3), t)
        assert int(a) == int(b)  # pure function of (logits, seed, position)
        assert int(a) != int(c) or int(a) != int(d)  # counters matter

    def test_batch_independence(self):
        """multinomial_with_seed's fix: the sample for a row must not depend
        on the other rows in the batch."""
        logits = jax.random.normal(jax.random.key(1), (8, 32))
        seeds = jnp.arange(8, dtype=jnp.int32)
        pos = jnp.full((8,), 5, jnp.int32)
        temps = jnp.full((8,), 0.7, jnp.float32)
        full = sample_batch(logits, seeds, pos, temps)
        solo = sample_batch(logits[3:4], seeds[3:4], pos[3:4], temps[3:4])
        assert int(full[3]) == int(solo[0])

    def test_top_k_truncates_and_reproduces(self):
        logits = jax.random.normal(jax.random.key(5), (64,))
        allowed = set(int(i) for i in jnp.argsort(logits)[-5:])
        seen = set()
        for pos in range(16):
            t = sample_token(logits, jnp.int32(3), jnp.int32(pos),
                             jnp.float32(1.5), jnp.int32(5))
            assert int(t) in allowed
            seen.add(int(t))
        assert len(seen) > 1  # actually stochastic within the truncated set
        a = sample_token(logits, jnp.int32(3), jnp.int32(7),
                         jnp.float32(1.5), jnp.int32(5))
        b = sample_token(logits, jnp.int32(3), jnp.int32(7),
                         jnp.float32(1.5), jnp.int32(5))
        assert int(a) == int(b)  # pure function of (logits, seed, pos, k)

    def test_window_positions_advance(self):
        logits = jax.random.normal(jax.random.key(2), (2, 4, 32))
        toks = sample_window(
            logits, jnp.array([1, 2], jnp.int32), jnp.array([0, 10], jnp.int32),
            jnp.full((2,), 0.8, jnp.float32),
        )
        assert toks.shape == (2, 4)
        # row 0 window position 2 == fresh sample at output index 2
        single = sample_token(logits[0, 2], jnp.int32(1), jnp.int32(2),
                              jnp.float32(0.8))
        assert int(toks[0, 2]) == int(single)
