"""Unit tests for DVR bookkeeping and the sampler (host-side logic)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dvr
from repro.serving.request import Request, SamplingParams, State
from repro.serving.sampler import sample_batch, sample_token, sample_window


def _req(committed, candidates, max_new=100, det=True):
    r = Request(rid=0, prompt=[1, 2, 3],
                sampling=SamplingParams(max_new_tokens=max_new,
                                        is_deterministic=det))
    r.committed = list(committed)
    r.candidates = list(candidates)
    return r


class TestDVRBookkeeping:
    def test_full_match_commits_all_plus_one(self):
        r = _req([10], [20, 30, 40])
        dvr.apply_verify_result(r, n_match=3, commit_tok=50)
        assert r.committed == [10, 20, 30, 40, 50]
        assert r.candidates == []
        assert r.num_rollbacks == 0

    def test_mismatch_commits_prefix_plus_verifier_token(self):
        r = _req([10], [20, 30, 40])
        dvr.apply_verify_result(r, n_match=1, commit_tok=99)
        assert r.committed == [10, 20, 99]
        assert r.num_rollbacks == 1
        assert r.num_recomputed_tokens == 2  # 30, 40 discarded

    def test_immediate_mismatch_still_progresses(self):
        r = _req([10], [20, 30])
        dvr.apply_verify_result(r, n_match=0, commit_tok=77)
        assert r.committed == [10, 77]  # >= 1 new token: forward progress
        assert r.num_recomputed_tokens == 2

    def test_budget_clamp(self):
        r = _req([10, 11, 12], [20], max_new=4)
        dvr.apply_verify_result(r, n_match=1, commit_tok=50)
        assert len(r.committed) == 4

    @settings(max_examples=30, deadline=None)
    @given(n_cand=st.integers(0, 7), n_match=st.integers(0, 7))
    def test_progress_invariant(self, n_cand, n_match):
        r = _req([1], list(range(100, 100 + n_cand)))
        before = len(r.committed)
        dvr.apply_verify_result(r, n_match=n_match, commit_tok=5)
        assert len(r.committed) >= before + 1  # ALWAYS >= 1 new token
        assert len(r.committed) <= before + n_cand + 1

    def test_build_verify_row_shapes(self):
        r = _req([10, 11], [20, 30])
        inputs, cand, cl, sp, ob = dvr.build_verify_row(r, window=5)
        assert inputs == [11, 20, 30, 0, 0]  # last committed + cands + pad
        assert cand == [20, 30, -1, -1]
        assert cl == 2
        assert sp == 3 + 2 - 1  # prompt_len + committed - 1
        assert ob == 2

    def test_ready_for_verify(self):
        r = _req([10], [20, 30, 40, 50], det=True)
        assert dvr.ready_for_verify(r, window=5)  # 4 == W-1 candidates
        r2 = _req([10], [20], det=True, max_new=100)
        assert not dvr.ready_for_verify(r2, window=5)
        r3 = _req([10], [20], det=True, max_new=2)  # done decoding
        assert dvr.ready_for_verify(r3, window=5)
        r4 = _req([10], [20, 30, 40, 50], det=False)
        assert not dvr.ready_for_verify(r4, window=5)

    def test_ready_for_verify_eager_partial_window(self):
        """min_candidates lowers the readiness bar (AdaptivePolicy's eager
        verification for demoted requests) but never below one candidate
        and never above the full window."""
        r = _req([10], [20], det=True, max_new=100)
        assert dvr.ready_for_verify(r, window=5, min_candidates=1)
        assert not dvr.ready_for_verify(r, window=5, min_candidates=2)
        assert dvr.ready_for_verify(r, window=5, min_candidates=0)  # floor 1
        full = _req([10], [20, 30, 40, 50], det=True)
        # min_candidates above W-1 clamps to the window
        assert dvr.ready_for_verify(full, window=5, min_candidates=99)
        empty = _req([10], [], det=True)
        assert not dvr.ready_for_verify(empty, window=5, min_candidates=1)


class TestAcceptanceTelemetry:
    """accept_ema: the per-request acceptance EMA AdaptivePolicy reads."""

    def test_sync_verdict_updates_ema(self):
        r = _req([10], [20, 30, 40, 50])
        assert r.accept_ema == 1.0  # optimistic start
        dvr.apply_verify_result(r, n_match=0, commit_tok=99)
        assert r.accept_ema == pytest.approx(0.5)  # alpha=0.5, sample 0.0

    def test_inflight_verdict_updates_ema(self):
        r = _req([10], [20, 30, 40, 50])
        fl = dvr.begin_inflight(r, window=5, submitted_at=1.0, ready_at=2.0)
        fl.n_match, fl.commit_tok = 2, 77
        dvr.apply_inflight_result(r, window=5)
        assert r.accept_ema == pytest.approx(0.75)  # sample 2/4

    def test_partial_window_counts_submitted_fraction(self):
        """An eager 1-candidate verdict weighs the same as a full window:
        the sample is n_match / submitted, so the EMA tracks flip
        probability, not window pacing."""
        r = _req([10], [20])
        dvr.apply_verify_result(r, n_match=1, commit_tok=30)
        assert r.accept_ema == 1.0  # 1/1 accepted: no decay
        r2 = _req([10], [20])
        dvr.apply_verify_result(r2, n_match=0, commit_tok=99)
        assert r2.accept_ema == pytest.approx(0.5)

    def test_ema_converges_under_constant_rollback(self):
        r = _req([10], [])
        for _ in range(6):
            r.candidates = [20, 30, 40, 50]
            dvr.apply_verify_result(r, n_match=0, commit_tok=99)
        assert r.accept_ema < 0.02  # demoted long before this

    def test_recovery_promotes(self):
        r = _req([10], [])
        r.accept_ema = 0.1
        for _ in range(3):
            r.candidates = [20, 30]
            dvr.apply_verify_result(r, n_match=2, commit_tok=40)
        assert r.accept_ema > 0.8  # above the promote threshold


class TestInflightVerify:
    """In-flight window bookkeeping (scheduler OverlapPolicy support)."""

    def _submit(self, committed, window_cands, past, window=5):
        r = _req(committed, list(window_cands) + list(past))
        fl = dvr.begin_inflight(r, window=window, submitted_at=1,
                                ready_at=1)
        assert fl.cands == list(window_cands)
        assert r.candidates == list(past)
        return r

    def test_begin_inflight_moves_window_out(self):
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        # window is out for verification; speculation continues behind it
        assert r.inflight.cands == [20, 30, 40, 50]
        assert r.total_generated == 1 + 4 + 2
        assert not dvr.ready_for_verify(r, window=5)  # no double-submit

    def test_full_match_agreeing_tail_survives(self):
        """Full match + commit token == first speculated-past token: the
        continuation was conditioned on exactly what got committed, so the
        remaining speculation stays valid."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        r.inflight.n_match, r.inflight.commit_tok = 4, 60
        dvr.apply_inflight_result(r)
        assert r.committed == [10, 20, 30, 40, 50, 60]
        assert r.candidates == [61]  # 60 was subsumed by the commit
        assert r.inflight is None
        assert r.num_rollbacks == 0

    def test_full_match_disagreeing_tail_invalidated(self):
        """Full match but the verifier's next token differs from the first
        speculated-past token: everything decoded past the window descends
        from a rolled-back token and must be recomputed."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61, 62])
        r.inflight.n_match, r.inflight.commit_tok = 4, 99
        dvr.apply_inflight_result(r)
        assert r.committed == [10, 20, 30, 40, 50, 99]
        assert r.candidates == []
        assert r.num_rollbacks == 1
        assert r.num_recomputed_tokens == 3  # 60, 61, 62

    def test_window_mismatch_invalidates_past_speculation(self):
        """Rollback inside the window reaches THROUGH to the speculated-past
        tokens: they extend a rejected candidate."""
        r = self._submit([10], [20, 30, 40, 50], [60, 61])
        r.inflight.n_match, r.inflight.commit_tok = 1, 77
        dvr.apply_inflight_result(r)
        assert r.committed == [10, 20, 77]
        assert r.candidates == []
        assert r.num_rollbacks == 1
        # 30, 40, 50 rejected in-window + 60, 61 speculated past it
        assert r.num_recomputed_tokens == 5

    def test_no_tail_full_match(self):
        r = self._submit([10], [20, 30], [])
        r.inflight.n_match, r.inflight.commit_tok = 2, 44
        dvr.apply_inflight_result(r)
        assert r.committed == [10, 20, 30, 44]
        assert r.num_rollbacks == 0

    def test_budget_clamp_drops_tail(self):
        r = self._submit([10], [20, 30, 40, 50], [60, 61], window=5)
        r.sampling.max_new_tokens = 6
        r.inflight.n_match, r.inflight.commit_tok = 4, 60
        dvr.apply_inflight_result(r)
        assert len(r.committed) == 6
        assert r.candidates == []  # budget reached: speculation moot

    def test_progress_invariant_inflight(self):
        for n_match in range(5):
            for past in ([], [60], [60, 61]):
                r = self._submit([1], [20, 30, 40, 50], past)
                r.inflight.n_match, r.inflight.commit_tok = n_match, 5
                before = len(r.committed)
                dvr.apply_inflight_result(r)
                assert len(r.committed) >= before + 1
                assert r.inflight is None


class TestStateMachine:
    """AWAITING_VERIFY wiring: the state is truthful, not decorative.

    A det request is AWAITING_VERIFY exactly while it cannot take a
    fast-path token because it is gated on verification — window full, or
    budget covered by outstanding speculation.  Every verdict (sync or
    in-flight) returns it to RUNNING."""

    def test_window_full_awaits_verify(self):
        r = _req([10], [20, 30, 40])
        r.state = State.RUNNING
        r.candidates.append(50)  # 4 == W-1 for window 5
        dvr.mark_window_state(r, window=5)
        assert r.state is State.AWAITING_VERIFY

    def test_partial_window_keeps_running(self):
        r = _req([10], [20])
        r.state = State.RUNNING
        dvr.mark_window_state(r, window=5)
        assert r.state is State.RUNNING

    def test_budget_covered_by_speculation_awaits(self):
        r = _req([10], [20, 30], max_new=3)  # total_generated == budget
        r.state = State.RUNNING
        dvr.mark_window_state(r, window=5)
        assert r.state is State.AWAITING_VERIFY

    def test_sync_verdict_returns_to_running(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        dvr.apply_verify_result(r, n_match=2, commit_tok=99)
        assert r.state is State.RUNNING

    def test_begin_inflight_resumes_speculation(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        dvr.begin_inflight(r, window=5, submitted_at=1, ready_at=2)
        assert r.state is State.RUNNING  # window out: decoding resumes

    def test_begin_inflight_with_exhausted_budget_stays_awaiting(self):
        r = _req([10], [20, 30, 40, 50], max_new=5)
        r.state = State.AWAITING_VERIFY
        dvr.begin_inflight(r, window=5, submitted_at=1, ready_at=2)
        assert r.state is State.AWAITING_VERIFY

    def test_inflight_verdict_returns_to_running(self):
        r = _req([10], [20, 30, 40, 50])
        r.state = State.AWAITING_VERIFY
        fl = dvr.begin_inflight(r, window=5, submitted_at=1, ready_at=2)
        fl.n_match, fl.commit_tok = 4, 60
        dvr.apply_inflight_result(r, window=5)
        assert r.state is State.RUNNING

    def test_inflight_verdict_stays_awaiting_when_leftovers_cover_budget(self):
        """Truthfulness after an in-flight verdict: if surviving
        speculated-past candidates already cover the output budget, the
        request still cannot take a fast-path token — it awaits the next
        verify launch, not decoding."""
        r = _req([10], [20, 30, 40, 50, 60, 61], max_new=7)
        fl = dvr.begin_inflight(r, window=5, submitted_at=1, ready_at=2)
        fl.n_match, fl.commit_tok = 4, 60  # full match, tail survives
        dvr.apply_inflight_result(r, window=5)
        assert r.committed == [10, 20, 30, 40, 50, 60]
        assert r.candidates == [61]  # 6 committed + 1 candidate == budget 7
        assert r.done_decoding()
        assert r.state is State.AWAITING_VERIFY

    def test_finished_is_never_clobbered(self):
        r = _req([10], [20])
        r.state = State.FINISHED
        dvr.apply_verify_result(r, n_match=1, commit_tok=30)
        assert r.state is State.FINISHED


class TestSampler:
    def test_greedy_first_max_tiebreak(self):
        logits = jnp.array([0.0, 5.0, 5.0, 1.0])
        tok = sample_token(logits, jnp.int32(0), jnp.int32(0), jnp.float32(0.0))
        assert int(tok) == 1

    def test_stochastic_is_positionally_keyed(self):
        logits = jax.random.normal(jax.random.key(0), (64,))
        t = jnp.float32(0.9)
        a = sample_token(logits, jnp.int32(7), jnp.int32(3), t)
        b = sample_token(logits, jnp.int32(7), jnp.int32(3), t)
        c = sample_token(logits, jnp.int32(7), jnp.int32(4), t)
        d = sample_token(logits, jnp.int32(8), jnp.int32(3), t)
        assert int(a) == int(b)  # pure function of (logits, seed, position)
        assert int(a) != int(c) or int(a) != int(d)  # counters matter

    def test_batch_independence(self):
        """multinomial_with_seed's fix: the sample for a row must not depend
        on the other rows in the batch."""
        logits = jax.random.normal(jax.random.key(1), (8, 32))
        seeds = jnp.arange(8, dtype=jnp.int32)
        pos = jnp.full((8,), 5, jnp.int32)
        temps = jnp.full((8,), 0.7, jnp.float32)
        full = sample_batch(logits, seeds, pos, temps)
        solo = sample_batch(logits[3:4], seeds[3:4], pos[3:4], temps[3:4])
        assert int(full[3]) == int(solo[0])

    def test_top_k_truncates_and_reproduces(self):
        logits = jax.random.normal(jax.random.key(5), (64,))
        allowed = set(int(i) for i in jnp.argsort(logits)[-5:])
        seen = set()
        for pos in range(16):
            t = sample_token(logits, jnp.int32(3), jnp.int32(pos),
                             jnp.float32(1.5), jnp.int32(5))
            assert int(t) in allowed
            seen.add(int(t))
        assert len(seen) > 1  # actually stochastic within the truncated set
        a = sample_token(logits, jnp.int32(3), jnp.int32(7),
                         jnp.float32(1.5), jnp.int32(5))
        b = sample_token(logits, jnp.int32(3), jnp.int32(7),
                         jnp.float32(1.5), jnp.int32(5))
        assert int(a) == int(b)  # pure function of (logits, seed, pos, k)

    def test_window_positions_advance(self):
        logits = jax.random.normal(jax.random.key(2), (2, 4, 32))
        toks = sample_window(
            logits, jnp.array([1, 2], jnp.int32), jnp.array([0, 10], jnp.int32),
            jnp.full((2,), 0.8, jnp.float32),
        )
        assert toks.shape == (2, 4)
        # row 0 window position 2 == fresh sample at output index 2
        single = sample_token(logits[0, 2], jnp.int32(1), jnp.int32(2),
                              jnp.float32(0.8))
        assert int(toks[0, 2]) == int(single)
