"""Tests for the determinism-contract static analyzer.

Two families:
* seeded-violation fixtures under ``tests/analysis_fixtures/`` — the
  checker MUST flag every one of them (a checker that stops firing is
  worse than no checker);
* the real repo sources MUST come out clean modulo the justified
  allowlist (the full jaxpr-tracing prover run is ``slow``; the default
  tier exercises the source passes and the comparison machinery).
"""

from __future__ import annotations

import importlib.util
from pathlib import Path

import pytest

from repro.analysis import check, hazards, kernel_lint, taint
from repro.analysis.jaxpr_utils import compare_canonical, dce
from repro.analysis.report import (
    AllowEntry,
    AllowlistError,
    Finding,
    Report,
    _parse_toml_allow,
    load_allowlist,
)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "analysis_fixtures"


def _fixture(name: str) -> Path:
    p = FIXTURES / name
    assert p.exists(), p
    return p


def _rules(findings) -> set:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# seeded violations: every fixture must be flagged


def test_fixture_adaptive_block_flagged():
    fs = kernel_lint.run_pass(REPO, files=[_fixture("fixture_adaptive_block.py")])
    assert "adaptive-block-size" in _rules(fs)
    assert "grid-reduction-extent" in _rules(fs)
    assert all(f.where.startswith("tests/analysis_fixtures/") for f in fs)


def test_fixture_bf16_accum_flagged():
    fs = kernel_lint.run_pass(REPO, files=[_fixture("fixture_bf16_accum.py")])
    accum = [f for f in fs if f.rule == "accum-dtype"]
    # both the VMEM scratch and the in-kernel preferred_element_type
    assert len(accum) == 2, fs
    assert {f.where.split("::")[1] for f in accum} == {"gemm_bf16_accum", "_kernel"}


def test_fixture_splitk_commit_flagged():
    fs = taint.scan_files(
        [_fixture("fixture_splitk_commit.py")], REPO, expected_roots=frozenset()
    )
    assert "fast-schedule-on-commit-path" in _rules(fs)
    assert "unresolved-schedule" in _rules(fs)
    # the threaded-parameter helper is fine: its binding is checked upstream
    assert not any("_project" == f.where.split("::")[-1] for f in fs)


def test_fixture_scatter_hazard_flagged():
    path = _fixture("fixture_scatter_hazard.py")
    spec = importlib.util.spec_from_file_location("fx_scatter", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    closed, batch = mod.analysis_trace()
    fs = hazards.scan_trace(dce(closed), batch, arch="fixture", kind="scatter")
    assert "scatter-add-overlap" in _rules(fs), fs
    flagged = [f for f in fs if f.rule == "scatter-add-overlap"]
    assert any("fixture_scatter_hazard" in f.where for f in flagged)


def test_fixture_mode_cli_exits_nonzero():
    rc = check.main(["--paths", str(_fixture("fixture_splitk_commit.py"))])
    assert rc == 1


def test_fixture_paged_runtime_extent_flagged():
    """A block-table walk run as a GRID axis: the reduction extent is the
    runtime table length (``tables.shape[1]``), not a literal — the
    shape-adaptive schedule the real paged kernel's fori_loop avoids."""
    path = _fixture("fixture_paged_runtime_extent.py")
    fs = kernel_lint.run_pass(REPO, files=[path])
    extent = [f for f in fs if f.rule == "grid-reduction-extent"]
    assert extent, fs
    assert all("fixture_paged_runtime_extent" in f.where for f in extent)
    # and the CLI treats it as a blocking finding
    assert check.main(["--paths", str(path)]) == 1


# ---------------------------------------------------------------------------
# the real repo must be clean (source passes; trace passes are slow-tier)


def test_repo_taint_clean():
    assert taint.run_pass(REPO) == []


def test_repo_kernel_lint_clean_modulo_allowlist():
    report = Report(
        allowlist=load_allowlist(REPO / "src/repro/analysis/allowlist.toml")
    )
    report.extend(kernel_lint.run_pass(REPO))
    assert report.ok, report.format()
    # the rmsnorm row-tile clamp is the one expected suppression
    assert [f.rule for f in report.suppressed] == ["adaptive-block-size"]


def test_commit_roots_annotated():
    # deleting a '# det: commit-path' annotation must be a finding, so
    # sabotage one root in a copied tree and re-run
    import shutil
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        for rel in ("src/repro/core", "src/repro/serving", "src/repro/models"):
            shutil.copytree(REPO / rel, root / rel)
        vf = root / "src/repro/core/verifier.py"
        vf.write_text(vf.read_text().replace("# det: commit-path\n", "", 1))
        fs = taint.run_pass(root)
        assert "unannotated-commit-root" in _rules(fs)


# ---------------------------------------------------------------------------
# allowlist mechanics


def test_allowlist_requires_justification():
    with pytest.raises(AllowlistError, match="justification"):
        _parse_toml_allow(
            '[[allow]]\npass = "hazards"\nrule = "x"\nwhere = "y"\n', "t"
        )
    with pytest.raises(AllowlistError, match="empty justification"):
        _parse_toml_allow(
            '[[allow]]\npass = "hazards"\nrule = "x"\nwhere = "y"\n'
            'justification = "  "\n',
            "t",
        )


def test_allowlist_stale_entry_flagged():
    report = Report(
        allowlist=[
            AllowEntry(
                pass_name="hazards", rule="gone", where="nowhere.py::f",
                justification="used to matter",
            )
        ]
    )
    report.finish(check_stale=True)
    assert [f.rule for f in report.findings] == ["stale-entry"]


def test_allowlist_suppression_is_exact_key_match():
    entry = AllowEntry(
        pass_name="kernel_lint", rule="accum-dtype", where="a.py::f",
        justification="j",
    )
    report = Report(allowlist=[entry])
    report.add(Finding("kernel_lint", "accum-dtype", "a.py::f", "m"))
    report.add(Finding("kernel_lint", "accum-dtype", "a.py::g", "m"))
    assert len(report.suppressed) == 1 and len(report.findings) == 1


def test_repo_allowlist_loads_and_is_justified():
    entries = load_allowlist(REPO / "src/repro/analysis/allowlist.toml")
    assert len(entries) >= 5
    assert all(len(e.justification) > 40 for e in entries)


# ---------------------------------------------------------------------------
# canonical-form comparison machinery (fast unit coverage of the prover)


def test_compare_affine_batch_dims_match():
    a = "x = foo[dim=104] (13, 8) out\ny = bar 1.5"
    b = "x = foo[dim=136] (17, 8) out\ny = bar 1.5"
    # 104 = 8*13, 136 = 8*17 (k=8, c=0); 8 = const (same both sides)
    assert compare_canonical(a, b, 13, 17) is None


def test_compare_affine_with_offset():
    # mamba conv-pad style: C + 3
    assert compare_canonical("pad 16", "pad 20", 13, 17) is None
    # rwkv shift style: C - 1
    assert compare_canonical("slice 12", "slice 16", 13, 17) is None


def test_compare_rejects_schedule_change():
    # split-K chunk 64 -> 128 would need c = -144, far beyond the affine
    # tolerance: a schedule difference cannot masquerade as a batch dim
    assert compare_canonical("chunk 64", "chunk 128", 13, 17) is not None


def test_compare_rejects_negative_slope():
    # integers that shrink as batch grows are never batch dims
    assert compare_canonical("v 17", "v 13", 13, 17) is not None


def test_compare_rejects_float_drift():
    # float literals must be bit-identical (e.g. 1/T scaling constants)
    assert compare_canonical("scale 0.0048", "scale 0.0036", 13, 17) is not None


def test_compare_reports_first_divergence():
    a = "same\nleft only line\nsame2"
    b = "same\nright only words\nsame2"
    idx, la, lb = compare_canonical(a, b, 13, 17)
    assert idx == 1 and "left" in la and "right" in lb


# ---------------------------------------------------------------------------
# the full prover (traces every arch class; minutes of work -> slow tier)


@pytest.mark.slow
def test_prover_certifies_all_arch_classes():
    from repro.analysis import invariance

    findings, certs, _ = invariance.run_pass()
    assert findings == [], [f.format() for f in findings]
    assert set(certs) == set(invariance.ARCH_CLASSES)
    for cert in certs.values():
        for kind_cert in cert["kinds"].values():
            assert kind_cert["invariant"] is True
            assert len(kind_cert["batches"]) >= 3
        assert cert["negative_control"]["schedules_differ"] is True
