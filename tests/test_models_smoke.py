"""Per-architecture smoke tests (deliverable (f)).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(<=4 layers, d_model<=512, <=4 experts), run one forward pass AND one train
step on CPU, assert output shapes and absence of NaNs; plus cached-prefill
vs full-causal bitwise-level consistency (the invariant DVR's KV-repair
correctness rests on).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import (
    build_cross_cache,
    forward,
    forward_train,
    init_cache,
    init_params,
)
from repro.models.multimodal import audio_frames
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train import make_train_step

# full model-zoo sweep: ~10 archs x (forward + train + cache consistency)
# compiles dozens of XLA programs — minutes on CPU, hence tier-2
pytestmark = pytest.mark.slow

ARCHS = list_archs()
B, S = 2, 16


def _setup(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = audio_frames(
            jax.random.key(2), B, cfg.encoder_seq_len, cfg.d_model
        )
    return cfg, params, toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params, toks, kw = _setup(arch)
    logits, aux = forward_train(params, cfg, toks, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    assert jnp.isfinite(aux["aux_loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg, params, toks, kw = _setup(arch)
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10),
                                   num_microbatches=1))
    batch = {
        "tokens": toks,
        "targets": jnp.roll(toks, -1, axis=1),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = kw["enc_embeds"]
    params2, opt2, metrics = step(params, init_opt_state(params), batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # parameters actually moved
    moved = any(
        not (a == b).all()
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCHS)
def test_cached_prefill_matches_train_forward(arch):
    """Prefill through the cache path must agree with the causal pass —
    the foundation of verifier/fast-path comparability."""
    cfg, params, toks, kw = _setup(arch)
    ref_logits, _ = forward_train(params, cfg, toks, **kw)
    cache = init_cache(cfg, B, 64)
    if cfg.family == "encdec":
        cache["cross"] = build_cross_cache(params, cfg, kw["enc_embeds"])
    got, _, _ = forward(params, cfg, toks, cache=cache,
                        start_pos=jnp.zeros(B, jnp.int32))
    assert jnp.allclose(got, ref_logits, atol=2e-4), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_consistent_with_prefill(arch):
    """Prefill(t0..t14) + decode(t15) == prefill(t0..t15), last logits."""
    cfg, params, toks, kw = _setup(arch)
    cache_a = init_cache(cfg, B, 64)
    cache_b = init_cache(cfg, B, 64)
    if cfg.family == "encdec":
        cross = build_cross_cache(params, cfg, kw["enc_embeds"])
        cache_a["cross"] = cross
        cache_b["cross"] = cross
    full, _, _ = forward(params, cfg, toks, cache=cache_a,
                         start_pos=jnp.zeros(B, jnp.int32))
    part, cache_b, _ = forward(params, cfg, toks[:, :-1], cache=cache_b,
                               start_pos=jnp.zeros(B, jnp.int32))
    last, _, _ = forward(params, cfg, toks[:, -1:], cache=cache_b,
                         start_pos=jnp.full((B,), S - 1, jnp.int32))
    assert jnp.allclose(last[:, 0], full[:, -1], atol=2e-4), arch


def test_sliding_window_variants_consistent():
    """Ring-buffer cache == full causal pass, when fed in window-sized
    chunks (the ring-buffer contract: <= window tokens per pass)."""
    cfg = dataclasses.replace(
        get_smoke_config("phi3-mini-3.8b"), attn_kind="sliding", window=8
    )
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 24), 0, cfg.vocab_size)
    ref_logits, _ = forward_train(params, cfg, toks)
    ring = init_cache(cfg, 1, 64)  # init_cache clamps attn capacity to window
    outs = []
    for s in range(0, 24, 8):
        lg, ring, _ = forward(params, cfg, toks[:, s : s + 8], cache=ring,
                              start_pos=jnp.full(1, s, jnp.int32))
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    assert jnp.allclose(got, ref_logits, atol=2e-4)


def test_ring_buffer_overflow_rejected():
    cfg = dataclasses.replace(
        get_smoke_config("phi3-mini-3.8b"), attn_kind="sliding", window=8
    )
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 200), 0, cfg.vocab_size)
    ring = init_cache(cfg, 1, 64)  # capacity = window + RING_SLACK = 136
    with pytest.raises(AssertionError, match="chunk"):
        forward(params, cfg, toks, cache=ring,
                start_pos=jnp.zeros(1, jnp.int32))


def test_moe_router_flips_under_schedule_change():
    """MoE expert selection itself is reduction-schedule sensitive — the
    family where the paper's O1 flips are most likely (DESIGN.md §4)."""
    from repro.core.determinism import Schedule

    cfg = get_smoke_config("kimi-k2-1t-a32b")
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    a, _ = forward_train(params, cfg, toks,
                         schedule=Schedule(splits=1, combine_dtype="bfloat16"))
    b, _ = forward_train(params, cfg, toks,
                         schedule=Schedule(splits=8, combine_dtype="bfloat16"))
    assert not (a == b).all()
